"""Benchmark aggregator: one module per paper table/figure (DESIGN.md §7).

Usage: PYTHONPATH=src python -m benchmarks.run [--scale smoke|full]
                                               [--only bench_build,...]
                                               [--trace]

Prints one CSV block per bench to stdout and writes both
results/bench/<name>.csv and results/bench/<name>.json (the JSON carries
rows + status + timing and is what CI uploads as an artifact and feeds
to benchmarks.check_recall_gate).

``--trace`` activates the obs span tracer (repro.obs) around each bench
and drops a Perfetto-loadable Chrome trace under
results/trace/<name>.trace.json — load it at https://ui.perfetto.dev;
see docs/observability.md for the span taxonomy.
"""

from __future__ import annotations

import argparse
import csv
import importlib
import io
import json
import os
import sys
import time

BENCHES = [
    "bench_build",          # Table 2
    "bench_qps_recall",     # Figure 7
    "bench_selectivity",    # Figure 8
    "bench_num_attrs",      # Figure 9
    "bench_partial_attrs",  # Figure 10
    "bench_cells",          # Figure 11
    "bench_intercell",      # Figure 12
    "bench_ablation",       # Figure 13
    "bench_outofcore",      # Figure 14 + Table 3
    "bench_disjunction",    # box-batched DNF planner vs per-box loop
    "bench_memory_budget",  # engine-mode sweep: incore / hybrid / ooc
    "bench_updates",        # streaming inserts/deletes/compaction
    "bench_kernels",        # kernel microbench
    "bench_serving",        # continuous-batching frontend vs serial loop
    "bench_sharding",       # mesh tier: placement balance + replica routing
]

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
TRACE_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                         "trace")


def _jsonable(o):
    """Benches occasionally leak numpy scalars/arrays into rows."""
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


def rows_to_csv(rows) -> str:
    if not rows:
        return "(no rows)\n"
    cols = []
    for r in rows:
        for k in r:
            if k not in cols:
                cols.append(k)
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    return buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--only", default="")
    ap.add_argument("--trace", action="store_true",
                    help="record obs spans per bench and write Perfetto "
                         "JSON under results/trace/")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    os.makedirs(OUT_DIR, exist_ok=True)
    for name in BENCHES:
        if only and name not in only:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        tracer = None
        try:
            if args.trace:
                from repro.obs.trace import Tracer, tracing
                tracer = Tracer()
                with tracing(tracer):
                    rows = mod.run(args.scale)
            else:
                rows = mod.run(args.scale)
            status = "ok"
        except Exception as e:  # keep the harness going
            rows = [{"bench": name, "error": f"{type(e).__name__}: {e}"}]
            status = "FAIL"
        dt = time.time() - t0
        if tracer is not None and tracer.spans:
            from repro.obs.export import write_chrome_trace
            path = os.path.join(TRACE_DIR, f"{name}.trace.json")
            write_chrome_trace(tracer, path)
            print(f"# trace: {os.path.relpath(path)} "
                  f"({len(tracer.spans)} spans)")
        csv_text = rows_to_csv(rows)
        print(f"### {name} [{status}] ({dt:.1f}s)")
        print(csv_text)
        with open(os.path.join(OUT_DIR, f"{name}.csv"), "w") as f:
            f.write(csv_text)
        payload = {"bench": name, "scale": args.scale, "status": status,
                   "elapsed_seconds": round(dt, 2), "rows": rows}
        with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
            json.dump(payload, f, indent=2, default=_jsonable)
        sys.stdout.flush()


if __name__ == "__main__":
    main()
