"""Serving bench (ISSUE 6): continuous batching vs a serial request loop.

Open-loop Poisson arrival harness over ``repro.serve.frontend`` with
background inserts. Three regimes per dataset:

  serial          — one ``Collection.search`` call per request,
                    back-to-back (the no-frontend baseline); its
                    closed-loop capacity also calibrates the arrival
                    rate for the open-loop regimes.
  frontend        — the continuous-batching front-end under Poisson
                    arrivals at ~5x the serial capacity. Must sustain
                    >= 1.3x the serial QPS at equal recall — and on the
                    in-core engine with identical per-request ids
                    (asserted here, not just in tests). (The bar was 3x
                    when the legacy dense scan re-traced its jit on
                    every call, which made the serial baseline
                    pathologically slow; with that fixed, coalescing
                    honestly buys fixed-overhead amortization only —
                    the gate tracks the measured speedup on top of this
                    hard floor.)
  frontend_ingest — same arrivals with background inserts riding the
                    loop and per-request latency SLOs; sheds expired
                    requests instead of serving dead answers. Inserts
                    are searchable from the buffer at once; the graph
                    splice (a stop-the-world flush whose inter-edge
                    repair costs tens of seconds at smoke scale, see
                    ROADMAP item 4) is cost-aware deferred by the
                    frontend while queued SLOs would expire — so the
                    regime measures read latency *under* live writes,
                    not flush throughput (that's bench_updates).

Time is virtual (``VirtualClock``): arrivals follow the seeded Poisson
process deterministically, while every pass advances the clock by its
*measured real* cost — so latency quantiles are real service time under
a reproducible arrival pattern.

Reported per row: p50/p95/p99 latency (ms), sustained QPS, shed rate,
mean batch occupancy, recall. ``check_recall_gate`` tracks the frontend
rows' p99 + shed-rate (direction-aware) and recall.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALES, dataset
from repro.api import AttrSchema, Collection, F
from repro.core.types import GMGConfig
from repro.serve.frontend import VectorFrontend, VirtualClock


def _filter_pool(attrs):
    """Mixed conjunctive / disjunctive / unfiltered request filters."""
    q20, q40, q60, q80 = (float(np.quantile(attrs[:, 0], p))
                          for p in (0.2, 0.4, 0.6, 0.8))
    t50 = float(np.quantile(attrs[:, 1], 0.5))
    return [
        F("attr0").between(q20, q80),
        (F("attr0") < q40) | (F("attr0") > q60),
        F("attr0").between(q20, q80) & (F("attr1") >= t50),
        None,
    ]


def _stream(vectors, filters, n_requests: int, rate: float, k: int,
            seed: int):
    """Deterministic Poisson arrival stream of single-query requests."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    t = np.cumsum(gaps)
    q = rng.standard_normal(
        (n_requests, vectors.shape[1])).astype(np.float32)
    return [{"t": float(t[i]), "q": q[i:i + 1],
             "f": filters[i % len(filters)], "k": k}
            for i in range(n_requests)]


def _quantiles_ms(lat):
    lat = np.asarray(lat, np.float64) * 1e3
    if lat.size == 0:
        return 0.0, 0.0, 0.0
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)),
            float(np.percentile(lat, 99)))


def _run_serial(col, stream):
    """One request at a time, back-to-back. Returns (row, results)."""
    results, lat, busy = [], [], 0.0
    clock = stream[0]["t"]
    for r in stream:
        t0 = time.perf_counter()
        res = col.search(r["q"], filters=r["f"], k=r["k"])
        dt = time.perf_counter() - t0
        busy += dt
        clock = max(clock, r["t"]) + dt
        lat.append(clock - r["t"])
        results.append(res)
    p50, p95, p99 = _quantiles_ms(lat)
    return {"mode": "serial", "qps": len(stream) / busy,
            "p50_ms": p50, "p95_ms": p95, "p99_ms": p99,
            "shed_rate": 0.0, "batch_occupancy": 0.0,
            "mean_service_s": busy / len(stream)}, results


def _run_frontend(col, stream, *, max_batch: int, max_wait: float,
                  slo: float | None = None, insert_every: int = 0,
                  ins_rows=None, flush_budget: float = 1e9,
                  idle_grace: float = 0.0):
    """Open-loop drive of the front-end over a timed arrival stream."""
    vc = VirtualClock(stream[0]["t"])
    fe = VectorFrontend(col, max_batch_queries=max_batch,
                        max_wait=max_wait, flush_budget=flush_budget,
                        idle_grace=idle_grace, clock=vc)
    rid_of, i, n_ins = {}, 0, 0
    while i < len(stream) or fe.queue:
        while i < len(stream) and stream[i]["t"] <= vc.t:
            r = stream[i]
            rid_of[i] = fe.submit(
                r["q"], filters=r["f"], k=r["k"],
                deadline=None if slo is None else r["t"] + slo)
            if insert_every and i % insert_every == insert_every - 1:
                v, a = ins_rows
                s = (n_ins * 8) % max(len(v) - 8, 1)
                fe.insert(v[s:s + 8], a[s:s + 8])
                n_ins += 1
            i += 1
        stats = fe.tick()
        if stats.get("waited") and fe.queue:
            oldest = min(r.t_submit for r in fe.queue)
            t_next = stream[i]["t"] if i < len(stream) else float("inf")
            vc.t = max(vc.t, min(t_next, oldest + fe.max_wait + 1e-9))
        elif not fe.queue and i < len(stream):
            vc.t = max(vc.t, stream[i]["t"])
    makespan = vc.t - stream[0]["t"]
    m = fe.metrics()
    row = {"qps": m["served"] / max(makespan, 1e-9),
           "p50_ms": m["p50_latency"] * 1e3,
           "p95_ms": m["p95_latency"] * 1e3,
           "p99_ms": m["p99_latency"] * 1e3,
           "shed_rate": m["shed_rate"],
           "batch_occupancy": m["mean_batch_occupancy"],
           "n_passes": m["n_passes"], "n_flushes": m["n_flushes"],
           "n_flush_deferrals": m["n_flush_deferrals"]}
    done = {rid: fe.take(rid) for rid in rid_of.values()
            if rid in fe.completed}
    results = [done.get(rid_of[j]) for j in range(len(stream))]
    return row, results


def _recall(col, stream, results):
    hit = total = 0
    for r, res in zip(stream, results):
        if res is None or getattr(res, "shed", False):
            continue
        qr = getattr(res, "result", res)
        if qr is None:
            continue
        ids = qr.ids
        truth = col.ground_truth(r["q"], filters=r["f"], k=r["k"])
        t = set(int(x) for x in truth[0] if x >= 0)
        if not t:
            continue
        hit += len(set(int(x) for x in ids[0] if x >= 0) & t)
        total += len(t)
    return hit / max(total, 1)


def run(scale: str = "smoke"):
    p = SCALES[scale]
    n_requests = {"smoke": 64, "full": 256}[scale]
    max_batch = {"smoke": 16, "full": 64}[scale]
    rows = []
    for name in p["datasets"]:
        v, a = dataset(name, p["n"])
        # dense_threshold pinned below bench scale: at smoke n the
        # production default (8192) routes every broad box to the exact
        # dense scan, and this bench exists to measure *traversal*
        # coalescing — dense-route serving perf lives in
        # bench_selectivity
        cfg = GMGConfig(seg_per_attr=(2, 2), intra_degree=16,
                        n_clusters=32, dense_threshold=256)
        # private build: the ingest regime mutates the collection, and
        # the cross-bench cache must stay pristine
        col = Collection.build(v, a, schema=AttrSchema.generic(a.shape[1]),
                               config=cfg, seed=0)
        filters = _filter_pool(a)
        # probe sized to max_batch so the widened warm-up pass compiles
        # the same padded batch shape the measured ticks will use
        probe = _stream(v, filters, max(len(filters) * 2, max_batch),
                        rate=1.0, k=10, seed=1)
        # warm the jit shapes the measured regimes hit: B=1 serial
        # calls plus widened passes at every pow2 occupancy up to
        # max_batch (ticks pad to pow2, so these are exactly the
        # program shapes a serving deployment would pre-compile)
        for r in probe:
            col.search(r["q"], filters=r["f"], k=r["k"])
        sz = 1
        while sz <= len(probe):
            col.search_many([(r["q"], r["f"], r["k"])
                             for r in probe[:sz]])
            sz *= 2

        base_stream = _stream(v, filters, n_requests, rate=1.0, k=10,
                              seed=2)
        serial_row, serial_res = _run_serial(col, base_stream)
        sbar = serial_row.pop("mean_service_s")
        # open-loop arrivals at ~5x serial capacity: the frontend must
        # absorb what the serial loop cannot
        rate = 5.0 / max(sbar, 1e-6)
        stream = _stream(v, filters, n_requests, rate=rate, k=10, seed=2)
        fe_row, fe_res = _run_frontend(col, stream, max_batch=max_batch,
                                       max_wait=0.0)
        # equal recall via equal answers: incore coalescing is
        # bit-identical to the serial loop, request by request
        for r_serial, r_fe in zip(serial_res, fe_res):
            assert r_fe is not None and not r_fe.shed
            np.testing.assert_array_equal(r_fe.result.ids, r_serial.ids)
        speedup = fe_row["qps"] / serial_row["qps"]
        assert speedup >= 1.3, (
            f"frontend {fe_row['qps']:.1f} qps < 1.3x serial "
            f"{serial_row['qps']:.1f} qps")
        rec = _recall(col, base_stream, serial_res)
        serial_row.update(bench="serving", dataset=name, recall=rec,
                          speedup=1.0)
        fe_row.update(bench="serving", dataset=name, mode="frontend",
                      recall=rec, speedup=speedup)
        rows += [serial_row, fe_row]

        # ingest regime: background writes + a per-request latency SLO
        slo = max(50 * sbar, 0.25)
        rng = np.random.default_rng(7)
        ins = (rng.standard_normal((256, v.shape[1])).astype(np.float32),
               rng.random((256, a.shape[1])).astype(np.float32))
        ing_row, ing_res = _run_frontend(
            col, stream, max_batch=max_batch, max_wait=0.0, slo=slo,
            insert_every=8, ins_rows=ins, flush_budget=10 * sbar,
            idle_grace=slo)
        ing_row.update(bench="serving", dataset=name,
                       mode="frontend_ingest",
                       recall=_recall(col, stream, ing_res),
                       speedup=ing_row["qps"] / serial_row["qps"])
        # live writes must not collapse the read path: the frontend's
        # cost-aware deferral keeps the stop-the-world splice out of the
        # SLO window (without it a single in-stream flush expired nearly
        # the whole queue — shed 0.86 at smoke)
        assert ing_row["shed_rate"] <= 0.5, (
            f"ingest regime shed {ing_row['shed_rate']:.2f} — the flush "
            "path is stalling reads")
        rows.append(ing_row)
    return rows
