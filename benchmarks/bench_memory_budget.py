"""Device-budget sweep across the three engine modes (ISSUE 3).

One collection, one workload, three declared ``device_budget_bytes``
regimes — the budget alone moves the execution across the mode matrix:

  fits_all    : the whole fp32 index fits            -> incore
  graph_over  : the fp32 graph exceeds the budget but the int8
                residents + a full graph cache fit   -> hybrid
  min_budget  : barely more than the int8 residents  -> ooc

plus a forced hybrid-vs-ooc pair at the ``graph_over`` budget — the
acceptance row: hybrid must beat the streaming engine's throughput at
equal (±tolerance) recall, since it keeps hot graph cells device-resident
across query batches instead of re-gathering/remapping/re-uploading its
whole window every call.
"""

from __future__ import annotations

from benchmarks import common
from repro.api import AttrSchema, Collection
from repro.core.runtime import cache_slot_bytes
from repro.core.search import ground_truth
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    from repro.core import gmg
    cfg = GMGConfig(seg_per_attr=(2, 2, 2), intra_degree=16, n_clusters=32,
                    batch_cells=3)
    idx = gmg.build_gmg(v, a, cfg, seed=0)
    schema = AttrSchema.generic(a.shape[1])
    base = Collection(index=idx, schema=schema)

    wl = make_queries(v, a, nq, 2, seed=210)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    p = SearchParams(k=10, ef=64)

    resident = base.out_of_core_resident_bytes()
    full_cache = cache_slot_bytes(idx) * idx.n_cells
    budgets = [
        ("fits_all", base.in_core_bytes() + (1 << 20)),
        ("graph_over", resident + full_cache + (1 << 16)),
        ("min_budget", (resident + base.hybrid_min_bytes()) // 2),
    ]
    assert budgets[1][1] < base.in_core_bytes(), \
        "graph_over regime must exclude the in-core engine"

    rows = []

    def measure(col: Collection, label: str, mode_used: str):
        res = col.search(wl.q, filters=(wl.lo, wl.hi), params=p)  # warm jit
        assert res.engine == mode_used
        qps, _ = common.timed_qps(
            lambda: col.search(wl.q, filters=(wl.lo, wl.hi), params=p),
            nq, warmup=0, iters=3)
        stats = dict(col.last_stats)
        return dict(
            bench="memory_budget", dataset=ds, budget=label,
            budget_mb=round((col.device_budget_bytes or 0) / 1e6, 2),
            mode=mode_used,
            recall=round(res.recall(tids), 4), qps=round(qps, 1),
            transfer_mb=round(stats.get("transfer_bytes", 0) / 1e6, 3))

    # the budget alone walks the mode matrix
    for label, budget in budgets:
        col = Collection(index=idx, schema=schema,
                         device_budget_bytes=budget)
        rows.append(measure(col, label, col.plan()["engine"]))

    # acceptance pair: same graph_over budget, modes forced
    for mode in ("hybrid", "ooc"):
        col = Collection(index=idx, schema=schema,
                         device_budget_bytes=budgets[1][1], mode=mode)
        rows.append(measure(col, "graph_over_forced", mode))
    return rows
