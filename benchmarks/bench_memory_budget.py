"""Device-budget sweep across the three engine modes (ISSUE 3 + 4).

One collection, one workload, declared ``device_budget_bytes`` regimes —
the budget alone moves the execution across the mode matrix:

  fits_all       : the whole fp32 index fits          -> incore
  graph_over     : the fp32 graph exceeds the budget but the int8
                   residents + a full graph cache fit  -> hybrid
  min_budget     : barely more than the int8 residents -> ooc

plus two forced pairs at fixed budgets:

  graph_over_forced : hybrid vs ooc at the graph_over budget — hybrid
      must beat the streaming engine's throughput at equal (±tolerance)
      recall, since it keeps hot graph cells device-resident across
      query batches instead of re-uploading its window every call.
  cache_pressure    : hybrid with the cache halved, size-aware arena +
      cache-aware wave order vs the PR-3 fixed-slot cache-blind
      baseline (``cache_policy="fixed"``). The ISSUE-4 acceptance row:
      the locality-aware runtime must cut warm ``transfer_bytes`` at
      equal (±0.005) recall. Asserted here so the row cannot silently
      stop meaning anything; the CI perf gate additionally tracks
      hit_rate / transfer_bytes / total_active against the committed
      baseline.

Rows carry the engine stats (``total_active``, ``hit_rate``,
``transfer_bytes``) for ``benchmarks.check_recall_gate``'s perf gate.
"""

from __future__ import annotations

from benchmarks import common
from repro.api import AttrSchema, Collection
from repro.core.runtime import cache_slot_bytes
from repro.core.search import ground_truth
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    from repro.core import gmg
    # dense_threshold pinned below bench scale: this bench measures the
    # streaming tiers' cache/transfer behavior, which the cost model's
    # dense route would bypass entirely at smoke n (see docs/tuning.md)
    cfg = GMGConfig(seg_per_attr=(2, 2, 2), intra_degree=16, n_clusters=32,
                    batch_cells=3, dense_threshold=256)
    idx = gmg.build_gmg(v, a, cfg, seed=0)
    schema = AttrSchema.generic(a.shape[1])
    base = Collection(index=idx, schema=schema)

    wl = make_queries(v, a, nq, 2, seed=210)
    tids, _ = ground_truth(v, a, wl.q, wl.lo, wl.hi, 10)
    p = SearchParams(k=10, ef=64)

    resident = base.out_of_core_resident_bytes()
    full_cache = cache_slot_bytes(idx) * idx.n_cells
    budgets = [
        ("fits_all", base.in_core_bytes() + (1 << 20)),
        ("graph_over", resident + full_cache + (1 << 16)),
        ("min_budget", (resident + base.hybrid_min_bytes()) // 2),
    ]
    assert budgets[1][1] < base.in_core_bytes(), \
        "graph_over regime must exclude the in-core engine"
    # cache under pressure: room for roughly half the graph cells, so a
    # warm repeated workload still streams — the regime where cache-aware
    # wave order + size-aware slots pay off
    pressure = resident + full_cache // 2

    rows = []

    def measure(col: Collection, label: str, mode_used: str):
        res = col.search(wl.q, filters=(wl.lo, wl.hi), params=p)  # warm jit
        assert res.engine == mode_used
        qps, _ = common.timed_qps(
            lambda: col.search(wl.q, filters=(wl.lo, wl.hi), params=p),
            nq, warmup=0, iters=3)
        st = res.stats                 # typed EngineStats, not a key probe
        row = dict(
            bench="memory_budget", dataset=ds, budget=label,
            budget_mb=round((col.device_budget_bytes or 0) / 1e6, 2),
            mode=mode_used,
            recall=round(res.recall(tids), 4), qps=round(qps, 1),
            transfer_mb=round(st.transfer_bytes / 1e6, 3))
        if mode_used != "incore":      # engine stats the perf gate tracks
            row["transfer_bytes"] = int(st.transfer_bytes)
            row["total_active"] = int(st.total_active)
            if st.hit_rate is not None:
                row["hit_rate"] = round(float(st.hit_rate), 4)
            # double-buffered streaming counters (hybrid only): uploads
            # issued ahead of their wave and the fraction that got used
            if st.prefetches is not None:
                row["prefetches"] = int(st.prefetches)
            if st.prefetch_hits is not None:
                row["prefetch_hits"] = int(st.prefetch_hits)
            if st.prefetch_hit_rate is not None:
                row["prefetch_hit_rate"] = round(
                    float(st.prefetch_hit_rate), 4)
        rows.append(row)
        return row

    # the budget alone walks the mode matrix
    for label, budget in budgets:
        col = Collection(index=idx, schema=schema,
                         device_budget_bytes=budget)
        measure(col, label, col.plan()["engine"])

    # acceptance pair: same graph_over budget, modes forced
    for mode in ("hybrid", "ooc"):
        col = Collection(index=idx, schema=schema,
                         device_budget_bytes=budgets[1][1], mode=mode)
        measure(col, "graph_over_forced", mode)

    # ISSUE-4 acceptance pair: halved cache, size-aware vs PR-3 baseline
    by_policy = {}
    for policy in ("size_aware", "fixed"):
        col = Collection(index=idx, schema=schema,
                         device_budget_bytes=pressure, mode="hybrid",
                         cache_policy=policy)
        by_policy[policy] = measure(col, f"cache_pressure_{policy}",
                                    "hybrid")
    arena, fixed = by_policy["size_aware"], by_policy["fixed"]
    assert arena["transfer_bytes"] < fixed["transfer_bytes"], (
        "cache-aware scheduling + size-aware slots must reduce warm "
        f"transfer vs the fixed-slot baseline: {arena['transfer_bytes']} "
        f"vs {fixed['transfer_bytes']}")
    assert abs(arena["recall"] - fixed["recall"]) <= 0.005, (
        "transfer win must come at equal recall: "
        f"{arena['recall']} vs {fixed['recall']}")
    # ISSUE-8 acceptance: under cache pressure the wave loop must be
    # actually double-buffering — uploads issued ahead of their wave,
    # and hit by it (the prefetch-hit counter cannot be zero here)
    assert arena.get("prefetch_hits", 0) > 0, (
        "cache-pressure regime ran without a single prefetch hit: "
        f"{arena}")
    return rows
