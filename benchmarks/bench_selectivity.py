"""Selectivity sweep 1e-4 -> 1.0: the cost model's routing regimes.

For each target selectivity the same workload runs twice on every
engine mode (incore / hybrid / ooc) through the public ``Collection``
facade: once with the per-box cost model ON (default ``SearchParams``)
and once with ``CostModel.off()`` — the ablation arm that forces every
box onto the traversal path, i.e. the pre-cost-model behavior.

Regime gates (the acceptance contract of the cost-model PR):

  - ultra-selective (target <= 1e-3): the fused masked-scan dense route
    must actually engage (``n_dense > 0``), beat the traversal arm on
    QPS (``speedup >= 1``) and give up no recall (within 0.02 — the
    dense route is exact within the selected cells, so in practice it
    *gains* recall here);
  - broad (target >= 0.5): the cost model must be a no-op — routes all
    broad, recall within 0.02, and QPS within wall-clock noise of the
    ablation arm (loose 0.5x floor: same code path, the only delta is
    the estimator's host-side pass).

Mid-range targets between the two scale ``ef`` instead of switching
algorithms; they are reported (route counts + recall both arms) but
only recall-gated, since wider pools intentionally trade QPS for
recall. Row estimates vs the dense scan's exact qualifying counts are
reported as ``est_rel_err`` (the estimator-quality satellite).

The recall gate (check_recall_gate.py) tracks each regime's cost-on
recall and on/off speedup across commits.
"""

from __future__ import annotations

import math

from benchmarks import common
from repro.core.search import recall_at_k
from repro.core.selectivity import CostModel
from repro.core.types import GMGConfig, SearchParams
from repro.data import make_queries

# target overall selectivities; <= 0.1 realized as m=2 conjunctions of
# width sqrt(target) (the paper's multi-attribute regime), broader ones
# as a single predicate (a 2-attr box at width ~0.7 would clip against
# the domain edges and miss the target)
TARGETS = (1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0)
DENSE_REGIME = 1e-3      # targets <= this must win via the dense route
BROAD_REGIME = 0.5       # targets >= this must be routing no-ops

# 4x4 grid (500 rows/cell at smoke scale) with a dense threshold well
# under n, so the sweep actually crosses the route boundaries instead
# of degenerating to one regime; see docs/tuning.md
_CFG = GMGConfig(seg_per_attr=(4, 4), intra_degree=16, n_clusters=32,
                 dense_threshold=256)


def _workload(v, a, nq, target):
    if target >= BROAD_REGIME:
        return make_queries(v, a, nq, 1, seed=60,
                            fixed_width=min(target, 1.0))
    return make_queries(v, a, nq, 2, seed=60,
                        fixed_width=math.sqrt(target))


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    col = common.built_collection(ds, n, cfg=_CFG)
    on = SearchParams(k=10, ef=64)
    off = SearchParams(k=10, ef=64, cost=CostModel.off())
    rows = []
    wls = []                 # keep workloads alive: truth() caches by id()
    for target in TARGETS:
        wl = _workload(v, a, nq, target)
        wls.append(wl)
        tids, _ = common.truth(ds, n, wl)
        for mode in ("incore", "hybrid", "ooc"):
            res_on = col.search(wl.q, (wl.lo, wl.hi), params=on,
                                engine=mode)
            qps_on, _ = common.timed_qps(
                lambda: col.search(wl.q, (wl.lo, wl.hi), params=on,
                                   engine=mode), nq)
            res_off = col.search(wl.q, (wl.lo, wl.hi), params=off,
                                 engine=mode)
            qps_off, _ = common.timed_qps(
                lambda: col.search(wl.q, (wl.lo, wl.hi), params=off,
                                   engine=mode), nq)
            r_on = recall_at_k(res_on.ids, tids)
            r_off = recall_at_k(res_off.ids, tids)
            speedup = qps_on / max(qps_off, 1e-9)
            st = res_on.stats
            row = dict(bench="selectivity", dataset=ds, sel=target,
                       mode=mode,
                       recall=round(r_on, 4),
                       recall_off=round(r_off, 4),
                       qps=round(qps_on, 1), qps_off=round(qps_off, 1),
                       speedup=round(speedup, 3),
                       n_dense=int(st.get("n_dense", 0)),
                       n_mid=int(st.get("n_mid", 0)),
                       n_broad=int(st.get("n_broad", 0)))
            if "est_rel_err_dense" in st:
                row["est_rel_err"] = round(st["est_rel_err_dense"], 4)
            rows.append(row)

            # per-regime gates (see module docstring)
            tag = f"sel={target} mode={mode}"
            assert r_on >= r_off - 0.02, \
                f"[{tag}] cost model lost recall: {r_on:.3f} < {r_off:.3f}"
            if target <= DENSE_REGIME:
                assert row["n_dense"] > 0, \
                    f"[{tag}] dense route never engaged"
                assert speedup >= 1.0, \
                    f"[{tag}] dense route slower than traversal " \
                    f"({qps_on:.0f} vs {qps_off:.0f} QPS)"
            if target >= BROAD_REGIME:
                assert row["n_dense"] == 0 and row["n_mid"] == 0, \
                    f"[{tag}] broad workload mis-routed: {row}"
                assert speedup >= 0.5, \
                    f"[{tag}] routing overhead on broad regime " \
                    f"({qps_on:.0f} vs {qps_off:.0f} QPS)"
    return rows
