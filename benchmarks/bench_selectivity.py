"""Paper Figure 8: fixed range widths 1/64, 1/16, 1/4 across m."""

from __future__ import annotations

from benchmarks import common
from repro.core.baselines import postfilter_search, prefilter_search
from repro.core.search import recall_at_k
from repro.core.types import SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    idx = common.built_index(ds, n)
    s = common.searcher_for(idx)
    from repro.core.baselines import FlatBaseline
    flat = common._CACHE.setdefault(("flat", ds, n),
                                    FlatBaseline.build(v, a, degree=16))
    rows = []
    for m in (1, 2):
        for width in (1 / 64, 1 / 16, 1 / 4):
            wl = make_queries(v, a, nq, m, seed=60, fixed_width=width)
            tids, _ = common.truth(ds, n, wl)
            p = SearchParams(k=10, ef=64)
            ids, _ = s.search(wl.q, wl.lo, wl.hi, p)
            qps, _ = common.timed_qps(
                lambda: s.search(wl.q, wl.lo, wl.hi, p), nq)
            rows.append(dict(bench="selectivity", m=m, width=round(width, 4),
                             method="garfield",
                             recall=round(recall_at_k(ids, tids), 4),
                             qps=round(qps, 1)))
            ids, _ = prefilter_search(flat, wl.q, wl.lo, wl.hi, 10)
            qps, _ = common.timed_qps(
                lambda: prefilter_search(flat, wl.q, wl.lo, wl.hi, 10), nq)
            rows.append(dict(bench="selectivity", m=m, width=round(width, 4),
                             method="gpu_pre",
                             recall=round(recall_at_k(ids, tids), 4),
                             qps=round(qps, 1)))
            ids, _ = postfilter_search(flat, wl.q, wl.lo, wl.hi, 10)
            qps, _ = common.timed_qps(
                lambda: postfilter_search(flat, wl.q, wl.lo, wl.hi, 10), nq)
            rows.append(dict(bench="selectivity", m=m, width=round(width, 4),
                             method="cagra_post",
                             recall=round(recall_at_k(ids, tids), 4),
                             qps=round(qps, 1)))
    return rows
