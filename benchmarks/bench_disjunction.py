"""Disjunctive-filter bench: box-batched planner execution vs the naive
per-box Python loop (one engine pass per branch + host-side merge), with
recall of both against the exact union answer.

Tracks the tentpole claim: flattening every query's DNF boxes into one
widened device pass amortizes cell selection / ordering / traversal
dispatch across branches, where the loop pays it once per branch.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.api import F


def _branch_exprs(attrs: np.ndarray, n_branches: int):
    """n_branches disjoint ~10%-selectivity quantile windows on attr0."""
    qs = np.quantile(attrs[:, 0].astype(np.float64),
                     np.linspace(0.0, 1.0, 2 * n_branches + 1))
    return [F("attr0").between(float(qs[2 * i]), float(qs[2 * i + 1]))
            for i in range(n_branches)]


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    rows = []
    for ds in sc["datasets"]:
        n, nq = sc["n"], sc["n_queries"]
        v, a = common.dataset(ds, n)
        col = common.built_collection(ds, n)
        wl = common.make_queries(v, a, nq, 1, seed=77)
        q = wl.q
        for nb in (2, 4):
            branches = _branch_exprs(a, nb)
            expr = branches[0]
            for br in branches[1:]:
                expr = expr | br
            truth = col.ground_truth(q, filters=expr, k=10)

            res = col.search(q, filters=expr, k=10)          # compile warm
            n_boxes = col.last_stats["planner"]["n_boxes"]
            qps, _ = common.timed_qps(
                lambda: col.search(q, filters=expr, k=10), nq)
            rows.append(dict(bench="disjunction", dataset=ds,
                             n_branches=nb, method="box_batched",
                             n_boxes=n_boxes,
                             recall=round(res.recall(truth), 4),
                             qps=round(qps, 1)))

            def per_box_loop():
                acc = col.search(q, filters=branches[0], k=10)
                for br in branches[1:]:
                    acc = acc.merge(col.search(q, filters=br, k=10))
                return acc

            acc = per_box_loop()                             # compile warm
            qps, _ = common.timed_qps(per_box_loop, nq)
            rows.append(dict(bench="disjunction", dataset=ds,
                             n_branches=nb, method="per_box_loop",
                             n_boxes=nb * nq,
                             recall=round(acc.recall(truth), 4),
                             qps=round(qps, 1)))
    return rows
