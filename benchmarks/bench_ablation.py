"""Paper Figure 13: ablations — inter-cell edges (a), cell ordering (b)."""

from __future__ import annotations

from benchmarks import common
from repro.core.search import recall_at_k
from repro.core.types import SearchParams
from repro.data import make_queries


def run(scale: str = "smoke"):
    sc = common.SCALES[scale]
    ds, n, nq = sc["datasets"][0], sc["n"], sc["n_queries"]
    v, a = common.dataset(ds, n)
    idx = common.built_index(ds, n)
    s = common.searcher_for(idx)
    rows = []
    for m in (1, 2):
        wl = make_queries(v, a, nq, m, seed=100 + m)
        tids, _ = common.truth(ds, n, wl)
        variants = {
            "full": SearchParams(k=10, ef=64),
            "no_inter_edges": SearchParams(k=10, ef=64,
                                           use_inter_edges=False),
            "no_ordering": SearchParams(k=10, ef=64, use_ordering=False),
        }
        for name, p in variants.items():
            ids, _ = s.search(wl.q, wl.lo, wl.hi, p)
            qps, _ = common.timed_qps(
                lambda: s.search(wl.q, wl.lo, wl.hi, p), nq)
            rows.append(dict(bench="ablation", m=m, variant=name,
                             recall=round(recall_at_k(ids, tids), 4),
                             qps=round(qps, 1)))
    return rows
